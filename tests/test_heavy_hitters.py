"""Heavy-hitter padding-id regressions + vectorized DistPlan grouping.

Acceptance contract (ISSUE 4):
(a) ``triangle_heavy_hitters(k)`` never returns padding ids — fabricated
    ``(0, 0)`` edges (edge mode) or vertex ids >= n (vertex mode) — for
    ANY ``k``, on both backends. The distributed path used to mask padded
    lanes to ``0.0`` before ``top_k``, so ``k`` beyond a shard's real
    candidate count surfaced the padding;
(b) the sort-based ``build_plan`` groupings (accumulation / all_gather /
    triangle) produce arrays identical to the old O(shards*edges)
    boolean-scan loops.
"""
import numpy as np
import pytest

from repro import engine
from repro.core.hll import HLLConfig
from repro.distributed import sketch_dist as sd
from repro.graph import generators as gen

CFG = HLLConfig(p=8)
BACKENDS = ["local", "sharded"]

# a triangle plus a pendant edge: 4 real edges, none of them (0, 0)
TINY = np.array([[1, 2], [2, 3], [1, 3], [3, 4]], np.int32)
TINY_N = 5


def _build(edges, n, backend):
    return engine.build(edges, n, CFG, backend=backend,
                        shards=1 if backend == "sharded" else None)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", [1, 4, 5, 50])
def test_edge_mode_never_returns_padding_edges(backend, k):
    eng = _build(TINY, TINY_N, backend)
    total, vals, ids = eng.triangle_heavy_hitters(k=k, mode="edge")
    real = set(map(tuple, TINY.tolist()))
    assert len(ids) == min(k, len(TINY))      # trimmed, never fabricated
    assert len(vals) == len(ids)
    for e in ids.tolist():
        assert tuple(e) in real, f"fabricated edge {e} (k={k})"
    assert np.all(np.isfinite(vals))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", [1, 5, 8, 64])
def test_vertex_mode_never_returns_padded_vertex_ids(backend, k):
    eng = _build(TINY, TINY_N, backend)
    total, vals, ids = eng.triangle_heavy_hitters(k=k, mode="vertex")
    assert len(ids) == min(k, TINY_N)
    assert np.all((ids >= 0) & (ids < TINY_N)), \
        f"padded vertex id leaked: {ids} (k={k})"
    assert np.all(np.isfinite(vals))


@pytest.mark.parametrize("backend", BACKENDS)
def test_large_k_on_real_graph_agrees_with_small_k_prefix(backend):
    """k beyond the candidate count returns the full, correctly ranked set."""
    edges = gen.rmat(7, 6, seed=3)
    n = int(edges.max()) + 1
    eng = _build(edges, n, backend)
    _, v_small, i_small = eng.triangle_heavy_hitters(k=5)
    _, v_huge, i_huge = eng.triangle_heavy_hitters(k=10 * len(edges))
    assert len(i_huge) == len(edges)
    np.testing.assert_allclose(v_huge[:5], v_small, rtol=1e-6)
    # every returned id is a real undirected edge
    real = set(map(tuple, edges.tolist()))
    assert all(tuple(e) in real for e in i_huge.tolist())


def _old_group(rows: np.ndarray, owner: np.ndarray, num_shards: int):
    """The pre-vectorization per-shard boolean-scan grouping (reference)."""
    per = [rows[owner == s] for s in range(num_shards)]
    cap = max(max((len(p) for p in per), default=1), 1)
    cap = ((cap + 7) // 8) * 8
    return per, cap


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_build_plan_groupings_match_boolean_scan_reference(shards):
    edges = gen.rmat(8, 8, seed=5)
    n = int(edges.max()) + 1
    plan = sd.build_plan(edges, n, shards)
    v_loc = plan.v_loc
    directed = np.concatenate([edges, edges[:, ::-1]], axis=0)

    per, e_acc = _old_group(directed, directed[:, 0] // v_loc, shards)
    assert plan.acc_dst_local.shape == (shards, e_acc)
    acc_dst = np.zeros((shards, e_acc), np.int32)
    acc_key = np.zeros((shards, e_acc), np.uint32)
    acc_mask = np.zeros((shards, e_acc), bool)
    flat_src = np.zeros((shards, e_acc), np.int32)
    for s, p in enumerate(per):
        m = len(p)
        acc_dst[s, :m] = p[:, 0] - s * v_loc
        acc_key[s, :m] = p[:, 1].astype(np.uint32)
        acc_mask[s, :m] = True
        flat_src[s, :m] = p[:, 1]
    np.testing.assert_array_equal(plan.acc_dst_local, acc_dst)
    np.testing.assert_array_equal(plan.acc_key, acc_key)
    np.testing.assert_array_equal(plan.acc_mask, acc_mask)
    np.testing.assert_array_equal(plan.flat_dst_local, acc_dst)
    np.testing.assert_array_equal(plan.flat_src, flat_src)
    np.testing.assert_array_equal(plan.flat_mask, acc_mask)

    tri_per, e_tri = _old_group(edges, edges[:, 0] // v_loc, shards)
    assert plan.tri_u.shape == (shards, e_tri)
    tri_u = np.zeros((shards, e_tri), np.int32)
    tri_v = np.zeros((shards, e_tri), np.int32)
    tri_mask = np.zeros((shards, e_tri), bool)
    for s, p in enumerate(tri_per):
        m = len(p)
        tri_u[s, :m] = p[:, 0]
        tri_v[s, :m] = p[:, 1]
        tri_mask[s, :m] = True
    np.testing.assert_array_equal(plan.tri_u, tri_u)
    np.testing.assert_array_equal(plan.tri_v, tri_v)
    np.testing.assert_array_equal(plan.tri_mask, tri_mask)


def test_build_plan_empty_edges():
    """Grouping degenerates gracefully: all-padding panels, mask False."""
    plan = sd.build_plan(np.zeros((0, 2), np.int32), 16, 4)
    assert not plan.acc_mask.any() and not plan.tri_mask.any()
    assert not plan.ring_mask.any() and not plan.flat_mask.any()
